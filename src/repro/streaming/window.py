"""Sliding window of transactions as a device-resident ring of word-blocks.

The batch miner packs the whole database once (``bitmap.pack_transactions``)
and repacks from scratch on every change.  A sliding window makes that repack
the dominant cost, so the window is kept as a *ring of word-blocks* instead:

    ring[i, b*wpb : (b+1)*wpb]   words of block b for item i

Each micro-batch of transactions is packed into one block (``wpb`` uint32
words = ``block_txns`` transaction columns) and written over the expired
block *in place* with one ``dynamic_update_slice`` — the rest of the window
bitmap never moves, on host or device.  Support counting and intersection are
per-word elementwise, so the physical word order of the ring (which wraps)
never matters: any column permutation and any all-zero pad column leaves
every support unchanged.  That invariance is what makes the ring bit-exact
with a batch ``mine()`` over the same window contents (DESIGN.md §5).

The ring keeps a host mirror of the packed words so per-item support deltas
and the evicted block's co-occurrence delta can be formed without reading the
device array back.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core import bitmap as bm
from ..dist.compat import shard_map_unchecked
from ..dist.sharding import padded_word_count, shard_words

__all__ = ["WindowRing", "RingState"]


@dataclasses.dataclass
class RingState:
    """Serializable snapshot of a :class:`WindowRing` (DESIGN.md §10).

    Holds *logical* content only: the host mirror at the logical word width,
    per-slot transaction counts, and the ring cursor.  The device-resident
    ring — including shard padding and placement — is derived state,
    recomputed on restore from (host words, restoring mesh), which is exactly
    what lets a checkpoint taken on a 4-way word-sharded mesh restore onto 2
    devices, a 2x2 grid, or a single device bit-exactly.
    """
    n_items: int
    n_blocks: int
    block_txns: int
    words: np.ndarray                 # (n_items, n_words) uint32, logical
    block_counts: np.ndarray          # (n_blocks,) int64
    head: int
    filled: int
    n_advances: int
    txns: Optional[List[List[List[int]]]] = None   # per-slot, if kept

    def to_tree(self) -> Tuple[Dict[str, np.ndarray], dict]:
        """(array tree, JSON-able extra) for ``training.checkpoint``.  The
        ragged per-slot transaction lists are flattened to three int64
        vectors (slot counts / txn lengths / item ids) so the whole state is
        a flat dict of ndarrays."""
        tree: Dict[str, np.ndarray] = {
            "words": np.ascontiguousarray(self.words, dtype=np.uint32),
            "block_counts": np.asarray(self.block_counts, np.int64),
        }
        if self.txns is not None:
            tree["txn_slot_counts"] = np.asarray(
                [len(slot) for slot in self.txns], np.int64)
            tree["txn_lens"] = np.asarray(
                [len(t) for slot in self.txns for t in slot], np.int64)
            tree["txn_items"] = np.asarray(
                [i for slot in self.txns for t in slot for i in t], np.int64)
        extra = {"n_items": int(self.n_items),
                 "n_blocks": int(self.n_blocks),
                 "block_txns": int(self.block_txns),
                 "head": int(self.head), "filled": int(self.filled),
                 "n_advances": int(self.n_advances),
                 "has_txns": self.txns is not None}
        return tree, extra

    @classmethod
    def from_tree(cls, tree: Dict[str, np.ndarray], extra: dict) -> "RingState":
        txns = None
        if extra["has_txns"]:
            txns = []
            lens = iter(np.asarray(tree["txn_lens"], np.int64).tolist())
            items = np.asarray(tree["txn_items"], np.int64).tolist()
            pos = 0
            for count in np.asarray(tree["txn_slot_counts"], np.int64).tolist():
                slot = []
                for _ in range(count):
                    n = next(lens)
                    slot.append(items[pos: pos + n])
                    pos += n
                txns.append(slot)
        return cls(n_items=int(extra["n_items"]),
                   n_blocks=int(extra["n_blocks"]),
                   block_txns=int(extra["block_txns"]),
                   words=np.asarray(tree["words"], np.uint32),
                   block_counts=np.asarray(tree["block_counts"], np.int64),
                   head=int(extra["head"]), filled=int(extra["filled"]),
                   n_advances=int(extra["n_advances"]), txns=txns)


@partial(jax.jit, donate_argnums=(0,))
def _write_block_jit(ring: jax.Array, block: jax.Array, start: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_slice_in_dim(ring, block, start, axis=1)


def _write_block(ring: jax.Array, block: jax.Array, start: jax.Array) -> jax.Array:
    """Overwrite one block's word span in place (``ring`` is donated so the
    slide is a true in-place update on TPU/GPU; CPU has no donation and
    would warn once per compile — suppressed here, for this call only)."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        return _write_block_jit(ring, block, start)


def _make_sharded_writer(mesh: jax.sharding.Mesh, shard_axis: str,
                         local_w: int):
    """Shard-local block write for a word-sharded ring.

    ``dynamic_update_slice`` on a ``P(None, axis)`` operand makes GSPMD
    all-gather the *entire* ring onto every device each slide (measured:
    one ``all-gather`` of the full word axis per push).  Instead each shard
    rewrites only the written-span words it owns: the incoming block is
    replicated, every shard masks its own word-index range against
    ``[start, start+wpb)`` and selects — zero collectives in the lowered
    module, which is exactly what the §7 ownership contract (and the
    ``staticcheck`` ring-write contract) demands.
    """
    spec = jax.sharding.PartitionSpec(None, shard_axis)
    rep = jax.sharding.PartitionSpec()

    def _local_write(ring_local, block, start):
        lo = jax.lax.axis_index(shard_axis).astype(jnp.int32) * local_w
        widx = lo + jax.lax.iota(jnp.int32, ring_local.shape[1])
        rel = widx - start
        inside = (rel >= 0) & (rel < block.shape[1])
        src = jnp.clip(rel, 0, block.shape[1] - 1)
        return jnp.where(inside[None, :], block[:, src], ring_local)

    return jax.jit(
        shard_map_unchecked(_local_write, mesh=mesh,
                            in_specs=(spec, rep, rep), out_specs=spec),
        donate_argnums=(0,))


class WindowRing:
    """Fixed-capacity sliding window: ``n_blocks`` blocks of ``block_txns``
    transaction columns each (``block_txns`` must be a multiple of 32 so block
    boundaries are word boundaries).

    ``push(batch)`` packs the micro-batch into the next ring slot, evicting
    whatever block occupied it, and returns the (new, old) packed blocks so
    the caller can form incremental support/co-occurrence deltas.

    With a ``mesh``, the device ring is carried **word-sharded**
    (``P(None, shard_axis)``, DESIGN.md §7): each device holds every item
    row but only a word slice, so a window bigger than one device's memory
    stays resident — block writes update only the word span of the evicted
    block, which lands on the shard(s) owning those words.  The word axis is
    zero-padded to a shard multiple (pad words are popcount-neutral); the
    host mirror stays at the logical ``n_words``.  On a 2D grid mesh
    (DESIGN.md §8) the same ``P(None, "data")`` placement additionally
    replicates the ring over the class axis — exactly how the grid engine
    carries its frontier, so the ring feeds it with no re-placement.
    """

    def __init__(self, n_items: int, n_blocks: int, block_txns: int,
                 keep_transactions: bool = True,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 shard_axis: str = "data"):
        if n_blocks < 1:
            raise ValueError("need at least one block")
        if block_txns < bm.WORD_BITS or block_txns % bm.WORD_BITS:
            raise ValueError(f"block_txns must be a positive multiple of "
                             f"{bm.WORD_BITS}, got {block_txns}")
        self.n_items = int(n_items)
        self.n_blocks = int(n_blocks)
        self.block_txns = int(block_txns)
        self.wpb = block_txns // bm.WORD_BITS          # words per block
        self.n_words = self.n_blocks * self.wpb
        self.mesh = mesh
        self.shard_axis = shard_axis
        self.words = np.zeros((self.n_items, self.n_words), np.uint32)
        if mesh is not None:
            self.n_shards = int(mesh.shape[shard_axis])
            self.n_words_dev = padded_word_count(self.n_words, self.n_shards)
            self.device = shard_words(
                np.zeros((self.n_items, self.n_words_dev), np.uint32),
                mesh, shard_axis)
            self._write_sharded = _make_sharded_writer(
                mesh, shard_axis, self.n_words_dev // self.n_shards)
            # replicated placement for the incoming block / start scalar: a
            # bare device_put commits to one device and the writer dispatch
            # would reshard implicitly (blocked under transfer guards)
            self._rep_sharding = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
        else:
            self.n_shards = 1
            self.n_words_dev = self.n_words
            self.device = jnp.zeros((self.n_items, self.n_words), jnp.uint32)
            self._write_sharded = None
            self._rep_sharding = None
        self.block_counts = np.zeros(self.n_blocks, np.int64)  # txns per slot
        self.head = 0            # next slot to (over)write
        self.filled = 0          # slots holding live data
        self.n_advances = 0
        self._txns: Optional[List[List[Sequence[int]]]] = (
            [[] for _ in range(self.n_blocks)] if keep_transactions else None)

    # -- geometry -----------------------------------------------------------

    @property
    def n_txn(self) -> int:
        """Live transactions in the window (pad columns excluded)."""
        return int(self.block_counts.sum())

    @property
    def full(self) -> bool:
        return self.filled == self.n_blocks

    def _slot_span(self, slot: int) -> slice:
        return slice(slot * self.wpb, (slot + 1) * self.wpb)

    # -- the one mutating operation -----------------------------------------

    def push(self, batch: Sequence[Sequence[int]]):
        """Admit one micro-batch, evicting the expired block in place.

        Returns ``(new_block, old_block, n_evicted)`` — both ``(n_items, wpb)``
        uint32 host arrays (``old_block`` is all-zero while the window is
        still warming up).
        """
        if len(batch) > self.block_txns:
            raise ValueError(f"micro-batch of {len(batch)} txns exceeds "
                             f"block capacity {self.block_txns}")
        new_block = bm.pack_transactions(batch, self.n_items)
        if new_block.shape[1] < self.wpb:   # partial batch: zero-pad columns
            new_block = np.pad(
                new_block, ((0, 0), (0, self.wpb - new_block.shape[1])))
        slot = self.head
        span = self._slot_span(slot)
        old_block = self.words[:, span].copy()
        n_evicted = int(self.block_counts[slot])
        self.words[:, span] = new_block
        # Explicit uploads (never jnp.asarray on host state: staticcheck
        # RS005) so the slide loop stays clean under transfer guards.
        block_dev = jax.device_put(new_block, self._rep_sharding)
        start_dev = jax.device_put(np.int32(slot * self.wpb),
                                   self._rep_sharding)
        if self._write_sharded is not None:
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore", message="Some donated buffers were not usable")
                self.device = self._write_sharded(
                    self.device, block_dev, start_dev)
        else:
            self.device = _write_block(self.device, block_dev, start_dev)
        self.block_counts[slot] = len(batch)
        if self._txns is not None:
            self._txns[slot] = [list(t) for t in batch]
        self.head = (self.head + 1) % self.n_blocks
        self.filled = min(self.filled + 1, self.n_blocks)
        self.n_advances += 1
        return new_block, old_block, n_evicted

    # -- serializable state (DESIGN.md §10) ---------------------------------

    def snapshot_state(self) -> RingState:
        """Deep-copied logical state; safe to serialize while the ring keeps
        sliding."""
        return RingState(
            n_items=self.n_items, n_blocks=self.n_blocks,
            block_txns=self.block_txns, words=self.words.copy(),
            block_counts=self.block_counts.copy(), head=self.head,
            filled=self.filled, n_advances=self.n_advances,
            txns=([[list(t) for t in slot] for slot in self._txns]
                  if self._txns is not None else None))

    def restore_state(self, state: RingState) -> "WindowRing":
        """Adopt a snapshot's logical content; the device ring is *re-derived*
        by placing the host words under this ring's own mesh/spec, so the
        snapshot may come from any mesh factorization (or none)."""
        if (state.n_items, state.n_blocks, state.block_txns) != \
                (self.n_items, self.n_blocks, self.block_txns):
            raise ValueError(
                f"ring geometry mismatch: state has (items={state.n_items}, "
                f"blocks={state.n_blocks}, block_txns={state.block_txns}), "
                f"ring has ({self.n_items}, {self.n_blocks}, {self.block_txns})")
        self.words = np.array(state.words, np.uint32, copy=True)
        self.block_counts = np.array(state.block_counts, np.int64, copy=True)
        self.head = int(state.head)
        self.filled = int(state.filled)
        self.n_advances = int(state.n_advances)
        self._txns = ([[list(t) for t in slot] for slot in state.txns]
                      if state.txns is not None else None)
        if self.mesh is not None:
            self.device = shard_words(self.words, self.mesh, self.shard_axis)
        else:
            self.device = jax.device_put(self.words)
        return self

    @classmethod
    def from_state(cls, state: RingState,
                   mesh: Optional[jax.sharding.Mesh] = None,
                   shard_axis: str = "data") -> "WindowRing":
        """Rebuild a ring from a snapshot under a (possibly different) mesh."""
        ring = cls(state.n_items, state.n_blocks, state.block_txns,
                   keep_transactions=state.txns is not None,
                   mesh=mesh, shard_axis=shard_axis)
        return ring.restore_state(state)

    # -- introspection (tests / bench comparators) --------------------------

    def window_transactions(self) -> List[List[int]]:
        """The window's live transactions, oldest block first (requires
        ``keep_transactions=True``)."""
        if self._txns is None:
            raise RuntimeError("ring was built with keep_transactions=False")
        out: List[List[int]] = []
        oldest = self.head if self.full else 0
        for i in range(self.filled):
            slot = (oldest + i) % self.n_blocks
            out.extend(list(t) for t in self._txns[slot])
        return out

    def validate(self) -> None:
        """Host mirror == device ring, per-slot supports consistent.

        Raises ``RuntimeError`` on any violation — these are real integrity
        checks (test hook *and* debugging aid), not ``assert`` statements,
        so they hold under ``python -O`` too.
        """
        dev = jax.device_get(self.device)
        if dev.shape != (self.n_items, self.n_words_dev):
            raise RuntimeError(
                f"device ring shape drifted: expected "
                f"{(self.n_items, self.n_words_dev)}, got {dev.shape}")
        if not np.array_equal(dev[:, : self.n_words], self.words):
            bad = np.nonzero((dev[:, : self.n_words] != self.words).any(0))[0]
            raise RuntimeError(
                f"device ring diverged from host mirror in {bad.size} word "
                f"column(s), first at word {int(bad[0])}")
        if self.n_words_dev > self.n_words and dev[:, self.n_words:].any():
            raise RuntimeError("shard-padding words beyond n_words must stay "
                               "all-zero but contain set bits")
        if (self.block_counts < 0).any() or \
                (self.block_counts > self.block_txns).any():
            raise RuntimeError(f"block_counts out of [0, {self.block_txns}]: "
                               f"{self.block_counts.tolist()}")
        for slot in range(self.n_blocks):
            span = self._slot_span(slot)
            per_item = bm.popcount_np(self.words[:, span]).sum(-1)
            if per_item.max(initial=0) > self.block_counts[slot]:
                raise RuntimeError(
                    f"slot {slot} holds an item with support "
                    f"{int(per_item.max())} > its {int(self.block_counts[slot])} "
                    f"live transactions — packed columns leaked past eviction")
