"""Transaction-database generators reproducing the paper's Table-2 datasets.

The paper evaluates on seven benchmarks (SPMF / FIMI repositories).  Those
files are not available offline, so this module generates databases with the
same *statistical shape* — transaction count, item universe, average width,
and density family — via:

  * :func:`quest` — the IBM Quest synthetic generator (Agrawal & Srikant,
    VLDB'94 §4.1): the exact process behind T10I4D100K / T40I10D100K /
    c20d10k.
  * :func:`attribute_table` — dense attribute-value data (chess, mushroom):
    each transaction picks one value per attribute, giving fixed width and
    small, heavily reused item universe.
  * :func:`clickstream` — sparse Zipf-distributed click data (BMS-WebView-1/2).

All generators are deterministic in (name, seed, scale).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

__all__ = ["quest", "attribute_table", "clickstream", "DatasetSpec",
           "PAPER_DATASETS", "generate", "materialize"]


def quest(
    n_txn: int,
    n_items: int,
    avg_txn_len: float,
    avg_pattern_len: float,
    n_patterns: int = 0,
    corruption: float = 0.5,
    seed: int = 0,
) -> List[List[int]]:
    """IBM Quest-style generator (T<avg_txn_len>I<avg_pattern_len>D<n_txn>)."""
    rng = np.random.default_rng(seed)
    n_patterns = n_patterns or max(n_items // 10, 10)

    # maximal potentially-frequent itemsets
    sizes = np.maximum(1, rng.poisson(avg_pattern_len, n_patterns))
    patterns: List[np.ndarray] = []
    prev = rng.choice(n_items, size=int(sizes[0]), replace=False)
    patterns.append(prev)
    for s in sizes[1:]:
        s = int(min(s, n_items))
        n_shared = min(int(round(rng.exponential(0.5) * s)), s, prev.shape[0])
        shared = rng.choice(prev, size=n_shared, replace=False) if n_shared else np.empty(0, np.int64)
        fresh = rng.choice(n_items, size=s - n_shared, replace=False)
        pat = np.unique(np.concatenate([shared, fresh]).astype(np.int64))
        patterns.append(pat)
        prev = pat
    weights = rng.exponential(1.0, n_patterns)
    weights /= weights.sum()

    txns: List[List[int]] = []
    for _ in range(n_txn):
        target = max(1, int(rng.poisson(avg_txn_len)))
        txn: set = set()
        guard = 0
        while len(txn) < target and guard < 40:
            guard += 1
            pat = patterns[rng.choice(n_patterns, p=weights)]
            keep = rng.random(pat.shape[0]) >= corruption * rng.random()
            picked = pat[keep]
            for it in picked:
                if len(txn) >= target:
                    break
                txn.add(int(it))
        if not txn:
            txn.add(int(rng.integers(n_items)))
        txns.append(sorted(txn))
    return txns


def attribute_table(
    n_txn: int,
    n_attributes: int,
    n_items: int,
    skew: float = 1.2,
    seed: int = 0,
) -> List[List[int]]:
    """Dense attribute-value data (chess/mushroom family):每 txn = one item per
    attribute; per-attribute value domains partition the item universe and
    values are drawn with a skewed (Zipf-ish) distribution so correlations and
    long frequent itemsets appear — the paper's "dense real-life" regime."""
    rng = np.random.default_rng(seed)
    # partition items into per-attribute domains (sizes >= 2 where possible)
    bounds = np.linspace(0, n_items, n_attributes + 1).astype(int)
    txns = np.zeros((n_txn, n_attributes), dtype=np.int64)
    for a in range(n_attributes):
        lo, hi = int(bounds[a]), int(bounds[a + 1])
        dom = max(hi - lo, 1)
        pvals = 1.0 / np.arange(1, dom + 1) ** skew
        pvals /= pvals.sum()
        txns[:, a] = lo + rng.choice(dom, size=n_txn, p=pvals)
    return [sorted(set(row.tolist())) for row in txns]


def clickstream(
    n_txn: int,
    n_items: int,
    avg_txn_len: float,
    zipf_a: float = 1.6,
    seed: int = 0,
) -> List[List[int]]:
    """Sparse clickstream data (BMS-WebView family): Zipf item popularity,
    short Poisson session lengths."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_items + 1, dtype=np.float64)
    p = ranks ** (-zipf_a)
    p /= p.sum()
    perm = rng.permutation(n_items)
    txns: List[List[int]] = []
    for _ in range(n_txn):
        size = max(1, int(rng.poisson(avg_txn_len)))
        picks = rng.choice(n_items, size=min(size, n_items), replace=False, p=p)
        txns.append(sorted(set(int(perm[i]) for i in picks)))
    return txns


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    """Paper Table-2 row + generator binding."""

    name: str
    kind: str                  # quest | attribute | clickstream
    n_txn: int
    n_items: int
    avg_width: float
    params: dict
    # paper's per-dataset experiment knobs:
    min_sups: tuple            # the varying min_sup sweep (Figs 8-14)
    tri_matrix: bool           # paper: False for BMS1/BMS2


PAPER_DATASETS = {
    "c20d10k": DatasetSpec("c20d10k", "quest", 10_000, 192, 20,
                           dict(avg_pattern_len=6, n_patterns=40),
                           min_sups=(0.5, 0.4, 0.3, 0.2, 0.1), tri_matrix=True),
    "chess": DatasetSpec("chess", "attribute", 3_196, 75, 37,
                         dict(n_attributes=37, skew=3.5),
                         min_sups=(0.9, 0.85, 0.8, 0.75, 0.7), tri_matrix=True),
    "mushroom": DatasetSpec("mushroom", "attribute", 8_124, 119, 23,
                            dict(n_attributes=23, skew=2.2),
                            min_sups=(0.4, 0.35, 0.3, 0.25, 0.2), tri_matrix=True),
    "BMS_WebView_1": DatasetSpec("BMS_WebView_1", "clickstream", 59_602, 497, 2.5,
                                 dict(zipf_a=1.35),
                                 min_sups=(0.005, 0.004, 0.003, 0.002, 0.001), tri_matrix=False),
    "BMS_WebView_2": DatasetSpec("BMS_WebView_2", "clickstream", 77_512, 3_340, 5,
                                 dict(zipf_a=1.35),
                                 min_sups=(0.005, 0.004, 0.003, 0.002, 0.001), tri_matrix=False),
    "T10I4D100K": DatasetSpec("T10I4D100K", "quest", 100_000, 870, 10,
                              dict(avg_pattern_len=4, n_patterns=100),
                              min_sups=(0.05, 0.04, 0.03, 0.02, 0.01), tri_matrix=True),
    "T40I10D100K": DatasetSpec("T40I10D100K", "quest", 100_000, 1_000, 40,
                               dict(avg_pattern_len=10, n_patterns=100),
                               min_sups=(0.05, 0.04, 0.03, 0.02, 0.01), tri_matrix=True),
}


def materialize(spec: DatasetSpec, n_txn: int, seed: int = 0) -> List[List[int]]:
    """Draw exactly ``n_txn`` transactions from a spec's generator family
    (shared by :func:`generate` and the streaming micro-batch source,
    ``repro.data.stream``)."""
    if spec.kind == "quest":
        txns = quest(n_txn, spec.n_items, spec.avg_width,
                     spec.params["avg_pattern_len"],
                     n_patterns=spec.params.get("n_patterns", 0), seed=seed)
    elif spec.kind == "attribute":
        txns = attribute_table(n_txn, spec.params["n_attributes"], spec.n_items,
                               skew=spec.params.get("skew", 1.2), seed=seed)
    elif spec.kind == "clickstream":
        txns = clickstream(n_txn, spec.n_items, spec.avg_width,
                           zipf_a=spec.params.get("zipf_a", 1.6), seed=seed)
    else:
        raise ValueError(spec.kind)
    return txns


def generate(name: str, scale: float = 1.0, seed: int = 0) -> tuple[List[List[int]], DatasetSpec]:
    """Materialize a paper dataset (``scale`` shrinks n_txn for CPU budgets;
    the Fig-16 scalability benchmark uses scale > 1)."""
    spec = PAPER_DATASETS[name]
    n_txn = max(16, int(round(spec.n_txn * scale)))
    return materialize(spec, n_txn, seed=seed), spec
