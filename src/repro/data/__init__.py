"""repro.data — transaction generators (paper datasets) + LM token pipeline."""
from .lm_pipeline import TokenPipeline
from .synthetic import (DatasetSpec, PAPER_DATASETS, attribute_table,
                        clickstream, generate, quest)

__all__ = ["TokenPipeline", "DatasetSpec", "PAPER_DATASETS", "attribute_table",
           "clickstream", "generate", "quest"]
