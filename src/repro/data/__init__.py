"""repro.data — transaction generators (paper datasets, micro-batch streams),
FIMI-format file ingestion (retail.dat et al.) + LM token pipeline."""
from .fimi import fimi_universe, load_fimi, parse_fimi, write_fimi
from .lm_pipeline import TokenPipeline
from .stream import stream_spec, transaction_stream
from .synthetic import (DatasetSpec, PAPER_DATASETS, attribute_table,
                        clickstream, generate, materialize, quest)

__all__ = ["TokenPipeline", "DatasetSpec", "PAPER_DATASETS", "attribute_table",
           "clickstream", "generate", "materialize", "quest",
           "transaction_stream", "stream_spec",
           "fimi_universe", "load_fimi", "parse_fimi", "write_fimi"]
