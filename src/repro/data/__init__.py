"""repro.data — transaction generators (paper datasets, micro-batch streams)
+ LM token pipeline."""
from .lm_pipeline import TokenPipeline
from .stream import stream_spec, transaction_stream
from .synthetic import (DatasetSpec, PAPER_DATASETS, attribute_table,
                        clickstream, generate, materialize, quest)

__all__ = ["TokenPipeline", "DatasetSpec", "PAPER_DATASETS", "attribute_table",
           "clickstream", "generate", "materialize", "quest",
           "transaction_stream", "stream_spec"]
