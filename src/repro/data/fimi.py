"""FIMI-format transaction files (retail.dat et al.).

The FIMI repository (fimi.uantwerpen.be) and SPMF distribute transaction
databases as plain text: one transaction per line, items as base-10
integers separated by whitespace.  Real files are ragged (every line its
own length), may carry trailing whitespace or CRLF endings, and sometimes
blank lines; item ids are non-negative but need not be dense or sorted.

This module parses that format into the same ``List[List[int]]`` the
in-memory generators produce, so a downloaded ``retail.dat`` drops
straight into ``pack_transactions`` / ``mine()`` and results become
comparable to the published literature instead of only to the synthetic
Table-2 shapes (tests assert bit-exact parity of the two ingestion
paths).  ``write_fimi`` is the inverse, used by the round-trip tests and
to export generated datasets for external tools.
"""
from __future__ import annotations

import os
from typing import Iterable, List, Sequence, Tuple

__all__ = ["parse_fimi", "load_fimi", "write_fimi", "fimi_universe"]


def parse_fimi(lines: Iterable[str]) -> List[List[int]]:
    """Parse FIMI lines into transactions (sorted, deduplicated item lists).

    Blank (or whitespace-only) lines are skipped — they are separators,
    not empty transactions; a file of N item lines yields exactly the N
    transactions every published parser reads from it.  Non-integer tokens
    and negative ids are rejected with the 1-based line number.
    """
    txns: List[List[int]] = []
    for ln, line in enumerate(lines, 1):
        toks = line.split()          # any whitespace runs, strips \r\n too
        if not toks:
            continue
        try:
            items = [int(t) for t in toks]
        except ValueError as e:
            raise ValueError(f"FIMI line {ln}: non-integer token ({e})") from None
        if any(i < 0 for i in items):
            raise ValueError(f"FIMI line {ln}: negative item id")
        txns.append(sorted(set(items)))
    return txns


def fimi_universe(txns: Sequence[Sequence[int]]) -> int:
    """Item-universe size for parsed transactions: ``max id + 1`` (FIMI ids
    index from 0 or 1 depending on the dataset; the bitmap encoder only
    needs an upper bound, so dense re-labeling is unnecessary)."""
    return max((max(t) for t in txns if t), default=-1) + 1


def load_fimi(path: str) -> Tuple[List[List[int]], int]:
    """Read a ``.dat`` file -> ``(transactions, n_items)``."""
    with open(path) as f:
        txns = parse_fimi(f)
    return txns, fimi_universe(txns)


def write_fimi(path: str, transactions: Sequence[Sequence[int]]) -> None:
    """Write transactions in FIMI format (space-separated, one per line).

    Items are written as given — unsorted or duplicated inputs survive the
    trip because parsing normalizes and the packed bitmap is OR-idempotent
    (the round-trip contract is bitmap equality, not byte equality).
    """
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        for t in transactions:
            f.write(" ".join(str(int(i)) for i in t) + "\n")
    os.replace(tmp, path)
