"""Micro-batch transaction streams for the sliding-window miner.

A stream is the paper's Table-2 data arriving continuously: fixed-size
micro-batches drawn from the same generator family as the batch dataset.
``drift_every`` re-seeds the generator's pattern pool every N batches, so the
frequent-pattern population shifts mid-stream — the scenario where classes
enter and leave the active set and the incremental miner's crossing
bookkeeping (DESIGN.md §5) actually fires.

Deterministic in (name, batch_txns, seed, drift_every): batch ``i`` of a
stream is a pure function of those, so benchmark runs and parity tests replay
the identical stream.
"""
from __future__ import annotations

from typing import Iterator, List, Optional

from .synthetic import PAPER_DATASETS, DatasetSpec, materialize

__all__ = ["transaction_stream", "stream_spec"]


def stream_spec(name: str) -> DatasetSpec:
    """The dataset spec a stream draws from (item universe, density family)."""
    return PAPER_DATASETS[name]


def transaction_stream(
    name: str,
    batch_txns: int,
    n_batches: int,
    seed: int = 0,
    drift_every: Optional[int] = None,
) -> Iterator[List[List[int]]]:
    """Yield ``n_batches`` micro-batches of ``batch_txns`` transactions.

    Batches inside one drift segment are consecutive chunks of a single
    generator draw, so they share the same pattern pool (a stationary
    regime).  With ``drift_every=k`` the pool is re-seeded every k batches:
    quest patterns / attribute skews / click popularity all shift, changing
    which items are frequent.
    """
    spec = PAPER_DATASETS[name]
    seg_len = drift_every if drift_every else n_batches
    emitted = 0
    segment = 0
    while emitted < n_batches:
        take = min(seg_len, n_batches - emitted)
        txns = materialize(spec, take * batch_txns, seed=seed + 7919 * segment)
        for b in range(take):
            yield txns[b * batch_txns: (b + 1) * batch_txns]
        emitted += take
        segment += 1
