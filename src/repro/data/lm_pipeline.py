"""Deterministic synthetic LM token pipeline.

Batch ``i`` is a pure function of ``(seed, i)``: a restarted or elastically
resharded run reproduces the exact token stream by construction (O(1)
skip-ahead — no data-loader state in checkpoints).  Tokens come from a
Zipf-weighted order-1 Markov chain so a small model has real structure to
learn (examples/train_lm.py shows the loss dropping).  A background prefetch
thread overlaps host generation with device steps.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

__all__ = ["TokenPipeline"]


class TokenPipeline:
    def __init__(self, vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                 n_states: int = 64, prefetch: int = 2,
                 shard_index: int = 0, shard_count: int = 1):
        self.vocab_size = int(vocab_size)
        self.batch = int(batch)
        self.seq_len = int(seq_len)
        self.seed = int(seed)
        self.shard_index = int(shard_index)
        self.shard_count = int(shard_count)
        rng = np.random.default_rng(seed ^ 0xC0FFEE)
        # shared Markov structure: n_states latent states, Zipf emissions
        self._trans = rng.dirichlet(np.full(n_states, 0.3), size=n_states)
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        zipf = ranks ** -1.1
        self._emit_base = zipf / zipf.sum()
        self._emit_shift = rng.integers(0, self.vocab_size, size=n_states)
        self._queue: Optional[queue.Queue] = None
        self._prefetch = prefetch
        self._stop = threading.Event()

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for global step ``step`` (this shard's slice)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 31 + self.shard_index)
        b = self.batch // self.shard_count
        toks = np.zeros((b, self.seq_len), np.int32)
        state = rng.integers(0, self._trans.shape[0], size=b)
        for t in range(self.seq_len):
            u = rng.random(b)
            cum = np.cumsum(self._trans[state], axis=1)
            state = (cum < u[:, None]).sum(axis=1)
            base = rng.choice(self.vocab_size, size=b, p=self._emit_base)
            toks[:, t] = (base + self._emit_shift[state]) % self.vocab_size
        return {"tokens": toks}

    # ---- prefetching iterator -------------------------------------------
    def __iter__(self) -> Iterator[dict]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[dict]:
        q: queue.Queue = queue.Queue(maxsize=self._prefetch)
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.2)
                    step += 1
                except queue.Full:
                    continue

        th = threading.Thread(target=worker, daemon=True)
        th.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
